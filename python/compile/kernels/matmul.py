"""Tiled Pallas matmul — the MXU-shaped compute hot-spot of the stack.

Design (DESIGN.md §4, "Hardware adaptation"):

* the grid iterates ``(M/bm, N/bn, K/bk)`` with the K axis innermost so a
  VMEM-resident ``(bm, bn)`` f32 accumulator tile is revisited across the
  K loop — the Pallas/TPU analogue of a CUDA threadblock tile loop;
* block sizes default to 128, matching the 128x128 MXU systolic array and
  the (8, 128) f32 VMEM tiling;
* ``jnp.dot(..., preferred_element_type=float32)`` keeps accumulation in
  f32 even for bf16 inputs (MXU-native mixed precision);
* ragged shapes are zero-padded up to block multiples in the wrapper and
  sliced back afterwards, keeping the kernel body branch-free;
* ``interpret=True`` so the lowering is plain HLO executable by the CPU
  PJRT client (a real-TPU build would drop the flag and emit Mosaic).

``matmul`` wraps the kernel in ``jax.custom_vjp`` so Layer-2 models can be
differentiated through it; both backward matmuls reuse the same kernel:
    dX = dY @ W^T       dW = X^T @ dY
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default MXU-aligned block sizes. f32 VMEM tiles are (8, 128); the MXU is
# a 128x128 systolic array, so 128-cubed blocks give full lane occupancy.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k: int):
    """One (bm, bn) output tile; grid = (M/bm, N/bn, K/bk), K innermost.

    ``acc_ref`` is a VMEM f32 scratch accumulator that lives across the K
    iterations of a fixed (i, j) tile; it is flushed to ``o_ref`` on the
    last K step (possibly downcasting to the output dtype).
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pad_to(a, rows, cols):
    r, c = a.shape
    if r == rows and c == cols:
        return a
    return jnp.pad(a, ((0, rows - r), (0, cols - c)))


def _ceil_to(v: int, b: int) -> int:
    return -(-v // b) * b


def _shrink(block: int, dim: int, lane: int) -> int:
    """Never use blocks larger than the padded problem dimension."""
    return min(block, _ceil_to(dim, lane))


def matmul_pallas_raw(
    x,
    w,
    *,
    bm: int = BLOCK_M,
    bn: int = BLOCK_N,
    bk: int = BLOCK_K,
    out_dtype=None,
):
    """Raw (non-differentiable) tiled Pallas matmul: ``x @ w``.

    x: (M, K), w: (K, N) -> (M, N). Shapes may be ragged; they are padded
    to block multiples and the result is sliced back.
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape
    out_dtype = out_dtype or jnp.result_type(x.dtype, w.dtype)

    # Sublane axis pads to 8, lane axis to 128 (f32 VMEM tiling); small
    # problems shrink the blocks so the grid never over-pads.
    bm = _shrink(bm, m, 8)
    bk = _shrink(bk, k, 128 if k >= 128 else 8)
    bn = _shrink(bn, n, 128 if n >= 128 else 8)

    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = _pad_to(x, mp, kp)
    wp = _pad_to(w, kp, np_)
    n_k = kp // bk

    out = pl.pallas_call(
        partial(_matmul_kernel, n_k=n_k),
        grid=(mp // bm, np_ // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=True,
    )(xp, wp)

    if (mp, np_) != (m, n):
        out = out[:m, :n]
    return out


@jax.custom_vjp
def matmul(x, w):
    """Differentiable tiled Pallas matmul ``x @ w`` (see module docs)."""
    return matmul_pallas_raw(x, w)


def _matmul_fwd(x, w):
    return matmul_pallas_raw(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    # Backward matmuls run through the same Pallas kernel (MXU path).
    dx = matmul_pallas_raw(g, w.T)
    dw = matmul_pallas_raw(x.T, g)
    return dx.astype(x.dtype), dw.astype(w.dtype)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_bytes(bm: int = BLOCK_M, bn: int = BLOCK_N, bk: int = BLOCK_K,
               in_bytes: int = 4) -> int:
    """Estimated per-core VMEM working set of one grid step.

    x tile (bm, bk) + w tile (bk, bn) at the input width, plus the f32
    accumulator (bm, bn) and the output tile (bm, bn). Used by the §Perf
    notes to check the schedule fits the ~16 MiB/core VMEM budget.
    """
    return in_bytes * (bm * bk + bk * bn) + 4 * (bm * bn) * 2
