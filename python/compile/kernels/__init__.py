"""Layer-1 Pallas kernels (interpret=True) + pure-jnp reference oracles.

The compute hot-spots of the FLANP/FedGATE stack:

- ``matmul``      — tiled, MXU-shaped block matmul (custom_vjp so the L2
                    model can be differentiated through it; the backward
                    pass reuses the same kernel).
- ``gate_update`` — fused FedGATE local update  w <- w - eta * (g - delta).
- ``axpy``        — fused generic  out <- a*x + y  used by server updates.
- ``bias_relu``   — fused bias-add + ReLU epilogue for the MLP.

All kernels run under ``interpret=True`` so their lowering is plain HLO
that the CPU PJRT client can execute (real-TPU Mosaic custom-calls cannot
run on CPU). See DESIGN.md §4 for the TPU adaptation rationale.
"""

from .matmul import matmul, matmul_pallas_raw  # noqa: F401
from .fused import gate_update, axpy, bias_relu  # noqa: F401
from . import ref  # noqa: F401
