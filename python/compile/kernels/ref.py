"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

pytest compares each kernel against these under shape/dtype sweeps
(hypothesis); the Rust NativeEngine mirrors the same math a third time so
the whole stack is differentially tested: pallas == jnp == rust.
"""

import jax.numpy as jnp


def matmul(x, w, out_dtype=None):
    out_dtype = out_dtype or jnp.result_type(x.dtype, w.dtype)
    return jnp.matmul(
        x, w, preferred_element_type=jnp.float32
    ).astype(out_dtype)


def gate_update(w, g, delta, eta):
    eta = jnp.asarray(eta, dtype=w.dtype)
    return w - eta * (g - delta)


def axpy(a, x, y):
    a = jnp.asarray(a, dtype=x.dtype)
    return a * x + y


def bias_relu(x, b):
    return jnp.maximum(x + b, 0.0)
