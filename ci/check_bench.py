#!/usr/bin/env python3
"""Gate CI on bench regressions.

Usage: check_bench.py <run.json> <baseline.json>

Compares a fresh `flanp-bench/v1` run (written by `cargo bench`, see
docs/perf.md for the schema) against the checked-in baseline
`ci/bench_baseline.json`. A bench regresses when its `min_ns` exceeds
the baseline's by more than the baseline's `tolerance` factor (default
1.25 = 25%). `min_ns` is used rather than `mean_ns` because the minimum
is far less sensitive to CI-runner noise.

Baseline entries with a null value are *pending*: they have never been
populated from a CI run and are skipped (printed, not failed). This is
how the baseline bootstraps — the first green CI run's artifact is
copied into ci/bench_baseline.json by hand.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 3:
        print(__doc__)
        return 2
    run_path, base_path = sys.argv[1], sys.argv[2]
    with open(run_path) as f:
        run = json.load(f)
    with open(base_path) as f:
        base = json.load(f)

    if run.get("schema") != "flanp-bench/v1":
        print(f"FAIL: {run_path} schema is {run.get('schema')!r}, "
              "expected 'flanp-bench/v1'")
        return 1

    tolerance = float(base.get("tolerance", 1.25))
    benches = run.get("benches", {})
    failures = []
    checked = skipped = 0
    for name, want in sorted(base.get("benches", {}).items()):
        if want is None or want.get("min_ns") is None:
            print(f"  pending  {name} (no baseline yet)")
            skipped += 1
            continue
        got = benches.get(name)
        if got is None:
            failures.append(f"{name}: present in baseline but missing "
                            f"from the run")
            continue
        want_ns, got_ns = float(want["min_ns"]), float(got["min_ns"])
        ratio = got_ns / want_ns if want_ns > 0 else float("inf")
        status = "ok" if ratio <= tolerance else "REGRESSED"
        print(f"  {status:<9} {name}: {got_ns:.0f} ns vs baseline "
              f"{want_ns:.0f} ns ({ratio:.2f}x, limit {tolerance:.2f}x)")
        checked += 1
        if ratio > tolerance:
            failures.append(f"{name}: {ratio:.2f}x > {tolerance:.2f}x")

    print(f"checked {checked}, pending {skipped}, failed {len(failures)}")
    if failures:
        print("FAIL:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
