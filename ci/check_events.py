#!/usr/bin/env python3
"""Validate a structured event log (and optionally a run summary).

Usage: check_events.py <events.jsonl> [run_summary.json] [--trace trace.csv]

Checks a `flanp-events/v1` event log written by `flanp run --events`
(see docs/observability.md for the schema):

  * the first line is the schema header {"schema": "flanp-events/v1"},
  * every following line is a JSON object with the fields
    round / stage / kind / client / detail, `kind` one of the known
    wire names, `client` an integer or null,
  * THE accounting invariant: in every round that prices a deadline,
    the per-client events partition the cohort —
    arrived + missed + cancelled + offline == the deadline event's
    `cohort` field. Wait rounds carry no per-client events.

With a `run_summary.json` argument it also checks the
`flanp-summary/v1` summary: the per-kind event counters equal the
event log's, and the span profiler reported a non-empty per-phase
host-time breakdown (at least one phase with count > 0).

With `--trace trace.csv` (the CSV `flanp run --out` writes) the
per-round missed / cancelled event counts are compared against the
trace's columns row by row — the two accounting paths must agree.

Exit codes mirror check_bench.py: 0 pass, 1 fail, 2 usage.
"""

import json
import sys

EVENTS_SCHEMA = "flanp-events/v1"
SUMMARY_SCHEMA = "flanp-summary/v1"

KINDS = {
    "cohort_selected", "cohort_padded", "cohort_reordered",
    "deadline", "wait",
    "arrived", "missed", "cancelled", "offline", "censored",
    "rerank", "tier_promote", "tier_demote",
    "stage", "lazy_round",
}

PER_CLIENT = {"arrived", "missed", "cancelled", "offline", "censored"}


def parse_events(path, failures):
    """Parse + field-check every line; return the event list."""
    events = []
    with open(path) as f:
        lines = f.read().splitlines()
    if not lines:
        failures.append(f"{path}: empty file")
        return events
    try:
        header = json.loads(lines[0])
    except json.JSONDecodeError as e:
        failures.append(f"{path}:1: bad header JSON: {e}")
        return events
    if header.get("schema") != EVENTS_SCHEMA:
        failures.append(f"{path}:1: schema is {header.get('schema')!r}, "
                        f"expected {EVENTS_SCHEMA!r}")
    for lineno, line in enumerate(lines[1:], start=2):
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            failures.append(f"{path}:{lineno}: bad JSON: {e}")
            continue
        ok = True
        for field in ("round", "stage", "kind", "client", "detail"):
            if field not in ev:
                failures.append(f"{path}:{lineno}: missing field "
                                f"{field!r}")
                ok = False
        if not ok:
            continue
        if ev["kind"] not in KINDS:
            failures.append(f"{path}:{lineno}: unknown kind "
                            f"{ev['kind']!r}")
            continue
        if not isinstance(ev["round"], int) or not isinstance(
                ev["stage"], int):
            failures.append(f"{path}:{lineno}: round/stage not integers")
            continue
        client_ok = ev["client"] is None or (
            isinstance(ev["client"], int) and not isinstance(
                ev["client"], bool))
        if not client_ok:
            failures.append(f"{path}:{lineno}: client is "
                            f"{ev['client']!r}, expected int or null")
            continue
        if ev["kind"] in PER_CLIENT and ev["client"] is None:
            failures.append(f"{path}:{lineno}: per-client kind "
                            f"{ev['kind']!r} without a client id")
            continue
        events.append(ev)
    return events


def check_accounting(events, failures):
    """arrived + missed + cancelled + offline == cohort per deadline
    round; returns {round: (missed, cancelled)} for the trace check."""
    rounds = {}
    for ev in events:
        t = rounds.setdefault(
            ev["round"],
            {"cohort": None, "arrived": 0, "missed": 0,
             "cancelled": 0, "offline": 0},
        )
        if ev["kind"] == "deadline":
            if t["cohort"] is not None:
                failures.append(f"round {ev['round']}: two deadline "
                                f"events")
            t["cohort"] = ev["detail"].get("cohort")
        elif ev["kind"] in ("arrived", "missed", "cancelled", "offline"):
            t[ev["kind"]] += 1
    deadline_rounds = 0
    by_round = {}
    for r in sorted(rounds):
        t = rounds[r]
        parts = (t["arrived"], t["missed"], t["cancelled"], t["offline"])
        if t["cohort"] is None:
            # a wait (or purely informational) round: nobody was priced,
            # so nobody may be booked
            if any(parts):
                failures.append(f"round {r}: per-client events "
                                f"{parts} without a deadline event")
            continue
        deadline_rounds += 1
        if sum(parts) != t["cohort"]:
            failures.append(
                f"round {r}: arrived {t['arrived']} + missed "
                f"{t['missed']} + cancelled {t['cancelled']} + offline "
                f"{t['offline']} = {sum(parts)} != cohort {t['cohort']}")
        by_round[r] = (t["missed"], t["cancelled"])
    if deadline_rounds == 0:
        failures.append("no deadline rounds in the event log")
    print(f"  accounting: {deadline_rounds} deadline rounds balanced")
    return by_round


def check_trace(trace_path, by_round, failures):
    """Per-round missed/cancelled columns of the trace CSV must equal
    the event counts."""
    with open(trace_path) as f:
        lines = f.read().splitlines()
    if not lines:
        failures.append(f"{trace_path}: empty trace")
        return
    cols = lines[0].split(",")
    try:
        i_round = cols.index("round")
        i_missed = cols.index("missed")
        i_cancelled = cols.index("cancelled")
    except ValueError as e:
        failures.append(f"{trace_path}: missing column: {e}")
        return
    checked = 0
    for line in lines[1:]:
        row = line.split(",")
        r = int(row[i_round])
        if r not in by_round:
            continue
        want = (int(row[i_missed]), int(row[i_cancelled]))
        got = by_round[r]
        if got != want:
            failures.append(f"round {r}: events (missed, cancelled) = "
                            f"{got} but trace row says {want}")
        checked += 1
    print(f"  trace: {checked} deadline rounds cross-checked against "
          f"{trace_path}")


def check_summary(path, events, failures):
    """Summary counters equal the log's; spans non-empty."""
    with open(path) as f:
        summary = json.load(f)
    if summary.get("schema") != SUMMARY_SCHEMA:
        failures.append(f"{path}: schema is {summary.get('schema')!r}, "
                        f"expected {SUMMARY_SCHEMA!r}")
        return
    counts = {}
    for ev in events:
        counts[ev["kind"]] = counts.get(ev["kind"], 0) + 1
    for kind, want in sorted(summary.get("events", {}).items()):
        got = counts.get(kind, 0)
        if int(want) != got:
            failures.append(f"{path}: events.{kind} = {int(want)} but "
                            f"the event log has {got}")
    spans = summary.get("spans", {})
    active = {name: s for name, s in spans.items()
              if s.get("count", 0) > 0}
    if not active:
        failures.append(f"{path}: span profiler reported no per-phase "
                        f"host time (empty spans)")
    else:
        breakdown = ", ".join(
            f"{name} {s['total_us']:.0f}us/{s['count']:.0f}"
            for name, s in sorted(active.items()))
        print(f"  spans: {breakdown}")


def main() -> int:
    args = sys.argv[1:]
    trace_path = None
    if "--trace" in args:
        i = args.index("--trace")
        try:
            trace_path = args[i + 1]
        except IndexError:
            print(__doc__)
            return 2
        del args[i:i + 2]
    if not 1 <= len(args) <= 2:
        print(__doc__)
        return 2

    failures = []
    events = parse_events(args[0], failures)
    print(f"  parsed {len(events)} events from {args[0]}")
    by_round = check_accounting(events, failures)
    if trace_path is not None:
        check_trace(trace_path, by_round, failures)
    if len(args) == 2:
        check_summary(args[1], events, failures)

    if failures:
        print("FAIL:")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print("PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
